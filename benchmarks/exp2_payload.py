"""Experiment 2 (paper Fig. 6): payload columns flow through the recursion.

N auxiliary varchar(20) columns are added to the table and to every
projection.  The paper's findings to reproduce:
  * PRecursive wins big (late materialization: N-independent level cost);
  * PRecursive run time ~independent of N;
  * TRecursive falls behind the row-store as N grows (columnar row
    reconstruction touches N+3 separate streams vs one contiguous row).
"""
from __future__ import annotations

from repro.core import EngineCaps
from repro.core.engine import RecursiveQuery, run_query

from .bench_util import emit, level_caps, time_call, tree_dataset

ENGINES = ("precursive", "trecursive", "rowstore")


def run(num_vertices: int = 200_000, height: int = 60,
        depths=(5, 10, 20), payloads=(2, 8, 16), repeat: int = 3) -> dict:
    out = {}
    for n in payloads:
        ds = tree_dataset(num_vertices, height, payload_cols=n)
        caps = level_caps(num_vertices, height)
        for depth in depths:
            for eng in ENGINES:
                q = RecursiveQuery(engine=eng, max_depth=depth,
                                   payload_cols=n, caps=caps)
                us = time_call(run_query, q, ds, 0, repeat=repeat)
                out[(eng, n, depth)] = us
            for eng in ENGINES:
                us = out[(eng, n, depth)]
                sp = out[("rowstore", n, depth)] / us
                emit(f"exp2/{eng}/N{n}/d{depth}", us,
                     f"speedup_vs_rowstore={sp:.2f}")
    return out


if __name__ == "__main__":
    run()
