"""Paper-claims validation: asserts the reproduction reproduces.

Each claim from §5/§6 of the paper is checked against the measured engine
times (CPU wall-clock; relative ratios are what the paper reports).  Output
rows carry PASS/FAIL so EXPERIMENTS.md §Paper-claims can quote them.
"""
from __future__ import annotations

from repro.core import EngineCaps
from repro.core.engine import RecursiveQuery, run_query

from .bench_util import emit, level_caps, time_call, tree_dataset


def run(num_vertices: int = 200_000, height: int = 2000, depth: int = 10,
        repeat: int = 3) -> dict:
    """Defaults put the result set at ~depth/height = 0.5% of the table —
    the paper's own regime ("rows scheduled to be materialized ... smaller
    by roughly 200 times", Exp 1)."""
    caps = level_caps(num_vertices, height, depth)

    def t(engine, n, d=depth, v=num_vertices):
        ds = tree_dataset(v, height, payload_cols=n)
        q = RecursiveQuery(engine=engine, max_depth=d, payload_cols=n,
                           caps=caps)
        return time_call(run_query, q, ds, 0, repeat=repeat)

    results = {}

    # C1 (paper: "PRecursive up to 6x over PostgreSQL", payload case)
    sp = t("rowstore", 16) / t("precursive", 16)
    results["C1"] = sp
    emit("claims/C1_precursive_vs_rowstore_N16", sp * 100,
         f"speedup={sp:.2f} {'PASS' if sp >= 3.0 else 'FAIL'} (paper: ~6x)")

    # C2 (paper: PRecursive ~independent of payload width N).  The
    # recursion itself is exactly N-flat (only `to` is read per level); the
    # residual sensitivity is the one final materialize (∝ N × result
    # rows, which the paper's plots also contain).  Threshold 1.5 with the
    # decomposition recorded.
    ratio = t("precursive", 16) / t("precursive", 2)
    results["C2"] = ratio
    emit("claims/C2_precursive_N_independence", ratio * 100,
         f"t(N16)/t(N2)={ratio:.2f} "
         f"{'PASS' if ratio <= 1.5 else 'FAIL'} (paper: ~flat; residual = "
         f"final materialize only)")

    # C3 (paper Exp1: TRecursive ~ PostgreSQL-with-Index when no payload;
    # both use the join index — our TRecursive expands through CSR, so the
    # index-enabled row store is the structurally matched comparator; the
    # paper notes TRecursive pulls slightly ahead with depth)
    r3 = t("trecursive", 0) / t("rowstore_index", 0)
    results["C3"] = r3
    emit("claims/C3_trecursive_close_to_rowstore_idx_N0", r3 * 100,
         f"t_ratio={r3:.2f} {'PASS' if 0.3 <= r3 <= 1.5 else 'FAIL'} "
         f"(paper: similar, TRecursive slightly ahead at depth)")

    # C4 (paper Exp3: rewriting gives TRecursive ~3x over the row-store)
    sp4 = t("rowstore_rewrite", 16) / t("trecursive_rewrite", 16)
    results["C4"] = sp4
    emit("claims/C4_trecursive_rewrite_speedup", sp4 * 100,
         f"speedup={sp4:.2f} {'PASS' if sp4 >= 2.0 else 'FAIL'} "
         f"(paper: ~3x)")

    # C5 (paper: the approach cannot be emulated in a row-store — the
    # rewrite must NOT bring the row-store near PRecursive)
    sp5 = t("rowstore_rewrite", 16) / t("precursive", 16)
    results["C5"] = sp5
    emit("claims/C5_rowstore_rewrite_still_behind", sp5 * 100,
         f"precursive_still_{sp5:.2f}x_faster "
         f"{'PASS' if sp5 >= 2.0 else 'FAIL'}")

    # C6 (beyond paper, informational): the dense bitmap engine's domain
    # is WIDE frontiers (exp1, height-60 trees: 7-12x over the row store);
    # in this deep-skinny regime positional expansion wins, as it should —
    # direction-optimizing `hybrid` picks per level.
    sp6 = t("precursive", 16) / t("bitmap", 16)
    results["C6"] = sp6
    emit("claims/C6_beyond_bitmap_vs_precursive_deep_regime", sp6 * 100,
         f"bitmap_speedup_vs_precursive={sp6:.2f} (beyond-paper, "
         f"regime-dependent; see exp1 for its 7-12x wide-frontier domain)")
    return results


if __name__ == "__main__":
    run()
