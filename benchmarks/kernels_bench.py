"""Kernel microbenchmarks: XLA-native ops vs the positional formulations.

Wall-times here are CPU (relative only); the TPU story is carried by the
roofline terms.  What these establish on ANY backend: bytes touched per BFS
level by each engine's hot loop, and embedding-bag lookup cost vs table
width (the N-independence of late materialization).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.csr import build_csr, expand_frontier
from repro.kernels.embedding_bag import embedding_bag, embedding_bag_ref
from repro.kernels.late_gather import late_gather_pallas, late_gather_ref

from .bench_util import emit, time_call


def run(repeat: int = 5) -> None:
    rng = np.random.default_rng(0)

    # positional gather: wide table, few positions (the Materialize op)
    for w in (4, 32, 128):
        tab = jnp.asarray(rng.standard_normal((1 << 18, w)).astype(np.float32))
        pos = jnp.asarray(rng.integers(0, 1 << 18, 4096).astype(np.int32))
        us = time_call(late_gather_ref, tab, pos, repeat=repeat)
        emit(f"kern/late_gather_xla/w{w}", us, "oracle")
        us2 = time_call(late_gather_pallas, tab, pos, repeat=repeat)
        emit(f"kern/late_gather_pallas_interp/w{w}", us2,
             "interpret-mode (not perf-representative)")

    # frontier expansion at growing frontier sizes
    src = jnp.asarray(rng.integers(0, 1 << 16, 1 << 18).astype(np.int32))
    csr = build_csr(src, 1 << 16)
    for f in (256, 4096):
        tg = jnp.asarray(rng.integers(0, 1 << 16, f).astype(np.int32))
        vd = jnp.ones((f,), bool)
        us = time_call(expand_frontier, csr, tg, vd, 1 << 15, repeat=repeat)
        emit(f"kern/frontier_expand/f{f}", us, "positions->positions")

    # embedding bag vs bag count
    tab = jnp.asarray(rng.standard_normal((1 << 16, 16)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 1 << 16, 1 << 14).astype(np.int32))
    seg = jnp.sort(jnp.asarray(rng.integers(0, 2048, 1 << 14)
                               .astype(np.int32)))
    us = time_call(embedding_bag_ref, tab, idx, seg, 2048, repeat=repeat)
    emit("kern/embedding_bag_xla/16k-into-2k", us, "oracle")


if __name__ == "__main__":
    run()
