"""Planner experiment: cost-based engine selection vs hand-forced engines.

For each paper-listing query shape (Listings 1.1/1.2/1.3 — traversal
columns, carried payloads, the Exp-3 rewrite) the planner parses the SQL,
prices every legal engine against the dataset statistics and picks one —
then we time its pick against EVERY forced engine.  The reported
``vs_best_forced`` ratio is the planner's regret: 1.00 means it picked the
fastest engine outright; the acceptance bar is <= 1.2x.

With ``--kernel`` (``include_kernel=True``) the Pallas ``frontier_expand``
kernel — plugged into ``CSRIndexJoin(expand_fn=)`` — is additionally timed
against the stock XLA expansion and offered to the planner as a physical
alternative (costed with a backend-dependent factor: cheap on TPU, interpret
mode elsewhere).
"""
from __future__ import annotations

from repro.core.engine import run_query
from repro.planner import paper_listing, plan

from .bench_util import emit, level_caps, time_call, time_ratio, \
    tree_dataset

LISTINGS = (1, 2, 3)


def run(num_vertices: int = 200_000, height: int = 60, depths=(5, 10),
        payloads: int = 16, repeat: int = 5,
        include_kernel: bool = False) -> dict:
    ds = tree_dataset(num_vertices, height, payload_cols=payloads)
    caps = level_caps(num_vertices, height)
    out = {}
    for depth in depths:
        for listing in LISTINGS:
            n_pay = 0 if listing == 1 else payloads
            sql = paper_listing(listing, root=0, depth=depth,
                                payload_cols=n_pay)
            report = plan(sql, ds, caps=caps)
            best = report.best
            # one measurement per candidate through the same run_query
            # path; the planner's time IS its pick's measurement, so the
            # ratio is pure selection regret, not duplicate-timing noise
            forced = {c.label: time_call(run_query, c.query, ds, 0,
                                         repeat=repeat)
                      for c in report.ranked if not c.use_kernel}
            best_forced = min(forced, key=forced.get)
            us_planner = forced[best.label]
            if best.label == best_forced:
                ratio = 1.0
            else:
                # the GATED regret is measured PAIRED (pick and best
                # forced interleaved): near-tied engines measured seconds
                # apart on a noisy host would otherwise flip this cell
                # past the 1.2 bar on machine weather alone
                q_best = next(c.query for c in report.ranked
                              if c.label == best_forced)
                ratio = time_ratio(lambda: run_query(best.query, ds, 0),
                                   lambda: run_query(q_best, ds, 0),
                                   repeat=max(repeat, 7))
            out[(listing, depth)] = (best.label, ratio)
            emit(f"planner/listing{listing}/d{depth}", us_planner,
                 f"chose={best.label},best_forced={best_forced},"
                 f"vs_best_forced={ratio:.2f}")

    if include_kernel:
        depth = depths[0]
        sql = paper_listing(1, root=0, depth=depth)
        report = plan(sql, ds, caps=caps, include_kernel=True)
        kern = next(c for c in report.ranked if c.use_kernel)
        stock = next(c for c in report.ranked
                     if c.engine == "precursive" and not c.use_kernel)
        us_kern = time_call(kern.run, ds, 0, repeat=repeat)
        us_stock = time_call(stock.run, ds, 0, repeat=repeat)
        rank = [c.label for c in report.ranked].index(kern.label) + 1
        emit(f"planner/kernel_expand/d{depth}", us_kern,
             f"vs_xla_expand={us_kern / max(us_stock, 1e-9):.2f},"
             f"planner_rank={rank}/{len(report.ranked)}")
        out[("kernel", depth)] = us_kern
    return out


if __name__ == "__main__":
    run()
