"""Timing + dataset helpers shared by the paper-experiment benchmarks."""
from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np

from repro.core.engine import Dataset
from repro.data.treegen import TreeSpec, make_edge_table


def time_call(fn: Callable, *args, warmup: int = 2, repeat: int = 5,
              **kwargs) -> float:
    """Median wall-time (us) of fn(*args); blocks on all outputs."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kwargs))
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kwargs))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def time_ratio(fn_a: Callable, fn_b: Callable, *, warmup: int = 2,
               repeat: int = 5) -> float:
    """Median of PAIRED a/b wall-time ratios, the two calls interleaved
    (a, b, a, b, ...).  Slow drifting load on a shared host hits both
    elements of a pair alike, so the ratio is far more stable than the
    quotient of two medians taken seconds apart — this is what the
    perf-gated comparison cells report."""
    for _ in range(warmup):
        jax.block_until_ready(fn_a())
        jax.block_until_ready(fn_b())
    ratios = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a())
        ta = time.perf_counter() - t0
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b())
        tb = time.perf_counter() - t0
        ratios.append(ta / max(tb, 1e-12))
    return float(np.median(ratios))


_DATASETS: dict = {}


def tree_dataset(num_vertices: int, height: int, payload_cols: int,
                 seed: int = 0) -> Dataset:
    key = (num_vertices, height, payload_cols, seed)
    if key not in _DATASETS:
        spec = TreeSpec(num_vertices=num_vertices, height=height,
                        payload_cols=payload_cols, seed=seed)
        _DATASETS[key] = Dataset.prepare(make_edge_table(spec),
                                         spec.num_vertices)
    return _DATASETS[key]


RESULTS: list[tuple[str, float, str]] = []


def emit(name: str, us: float, derived: str) -> None:
    """Print one CSV row and record it for ``run.py --json``."""
    RESULTS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}")


def level_caps(num_vertices: int, height: int, depth: int | None = None):
    """Volcano-style block sizing: frontier capacity ~ a few max level
    widths, result capacity ~ the depth-bounded result size (a real engine
    sizes blocks to the data, not the table — oversized static buffers
    charge every engine O(capacity) in padding work per level and in the
    final materialize)."""
    from repro.core import EngineCaps
    frontier = min(num_vertices, max(2048, 8 * num_vertices // max(height, 1)))
    result = num_vertices if depth is None else         min(num_vertices, frontier * (depth + 2))
    return EngineCaps(frontier=frontier, result=result)
