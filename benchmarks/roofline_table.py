"""Assemble the EXPERIMENTS.md roofline table from dry-run JSON results."""
from __future__ import annotations

import glob
import json
import os


def load_results(pattern: str = "results/dryrun_*.json") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(pattern)):
        try:
            with open(path) as f:
                rows.extend(json.load(f))
        except (OSError, json.JSONDecodeError):
            continue
    return rows


def fmt_s(x) -> str:
    try:
        x = float(x)
    except (TypeError, ValueError):
        return "-"
    if x == 0:
        return "0"
    if x < 1e-6:
        return f"{x*1e9:.1f}ns"
    if x < 1e-3:
        return f"{x*1e6:.1f}us"
    if x < 1:
        return f"{x*1e3:.2f}ms"
    return f"{x:.2f}s"


def markdown_table(rows: list[dict], mesh: str = "single_pod_16x16") -> str:
    lines = [
        "| arch | shape | compute | memory | collective | bound | "
        "frac | useful/HLO flops |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | "
                         f"SKIP ({r['skipped'][:40]}…) | | | | | |")
            continue
        if r.get("mesh") != mesh:
            continue
        uf = r.get("useful_flops_ratio")
        uf = f"{uf:.2f}" if isinstance(uf, (int, float)) else "-"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r.get('compute_s'))} | "
            f"{fmt_s(r.get('memory_s'))} | {fmt_s(r.get('collective_s'))} | "
            f"{r.get('dominant','-')} | {r.get('roofline_frac',0):.3f} | "
            f"{uf} |")
    return "\n".join(lines)


def main() -> None:
    rows = load_results()
    seen = set()
    dedup = []
    for r in reversed(rows):                 # newest file wins
        key = (r.get("arch"), r.get("shape"), r.get("mesh"),
               bool(r.get("skipped")))
        if key in seen:
            continue
        seen.add(key)
        dedup.append(r)
    dedup.reverse()
    print("## single-pod (16x16)\n")
    print(markdown_table(dedup, "single_pod_16x16"))
    print("\n## multi-pod (2x16x16)\n")
    print(markdown_table(dedup, "multi_pod_2x16x16"))


if __name__ == "__main__":
    main()
