"""Serving experiment: cached-plan dispatch latency for the traversal
serving layer (beyond-paper; the ROADMAP's many-users north star).

The cells:

* ``exp_serving/cold_plan`` — the FIRST request for a query shape: parse +
  statistics + costing + bucket layout + jit compiles.  Paid once per
  (shape, bucket signature).
* ``exp_serving/cached_dispatch`` — steady state: every request after the
  first hits the plan cache and the warm jitted dispatches; this is the
  number a serving SLO is written against.
* ``exp_serving/bucketed_vs_sequential`` — the reach-bucketed batch against
  a Python loop of single-root queries through the same chosen plan (the
  exp1 regression cell, measured at the serving layer; the gated ratio is
  PAIRED via ``time_ratio`` so shared-host drift cancels).
* ``exp_serving/calibrated_regret`` — the calibration gate: the warm
  traffic above fed the session's calibrator; REFIT the cost constants and
  re-rank — the calibrated pick's measured time vs the best forced engine
  (``calibrated_vs_best_forced``) must stay within the planner-regret bar,
  i.e. closing the feedback loop must not make selection WORSE.
* ``exp_serving/rehydrated_serving`` — the plan-store gate: save the
  session's plan store, rehydrate a fresh session from it, replay the same
  batch — ``rehydrated_match=1`` iff every root's row set is identical to
  the cold session's, with zero parse/stats/costing calls.
* ``exp_serving/disabled_tracer_ratio`` — the observability overhead gate:
  warm dispatch latency WITHOUT any tracer vs. with a DISABLED tracer
  installed on the session, as a paired ratio (``time_ratio``).  The
  disabled path must be free (gate: ratio >= 0.95), or tracing cannot be
  left wired into production serving.
* ``exp_serving/admission_overhead_ratio`` — the admission gate: warm
  dispatch latency with guards OFF vs. the default guarded front door, as
  a paired ratio on all-admitted traffic.  The ladder is one O(1) degree
  lookup + a few float ops per root, so it must be ~free (gate: ratio >=
  0.95), or it cannot be left on by default.
* ``exp_serving/guarded_p99_vs_unguarded`` — the admission payoff
  (informational, ungated): with the degrade budget tightened so the hub
  root depth-clamps, per-request p99 over the mixed hub+leaf batch vs.
  the unguarded session answering the same traffic.
* ``exp_serving/multiquery_throughput`` — the bit-parallel coalescing gate:
  32 single-root requests enqueued and flushed as ONE coalesced dispatch
  (whose multi-lane buckets plan the ``multiquery`` engine — up to 32
  roots as bits of one packed uint32 frontier word) against the
  reach-bucketed one-root-per-vmap-lane path on the same roots and bucket
  layout.  Row sets must match; the PAIRED ``multiquery_vs_bucketed``
  ratio is gated >= 4.0 in scripts/perf_gate.
"""
from __future__ import annotations

import os
import tempfile
import time

import jax
import numpy as np

from repro.core.engine import run_query
from repro.planner import ServingSession, paper_listing, plan

from .bench_util import emit, time_call, time_ratio, tree_dataset

BATCH_ROOTS = 8


def _row_set(r):
    n = int(r.count)
    ids = np.asarray(r.values["id"])[:n].tolist()
    depths = np.asarray(r.row_depths)[:n].tolist()
    return sorted(zip(ids, depths))


def run(num_vertices: int = 200_000, height: int = 60, depth: int = 5,
        repeat: int = 5) -> dict:
    ds = tree_dataset(num_vertices, height, payload_cols=0)
    sql = paper_listing(1, root=0, depth=depth)
    # a served batch mixes the hub root with leaf-ish roots — the regime
    # where lockstep batching regressed and bucketing pays
    roots = list(range(BATCH_ROOTS))
    out = {}

    session = ServingSession(ds)
    t0 = time.perf_counter()
    jax.block_until_ready([r.count for r in session.submit(sql, roots)])
    us_cold = (time.perf_counter() - t0) * 1e6
    out["cold"] = us_cold
    emit(f"exp_serving/cold_plan/d{depth}", us_cold,
         f"plans+compile,batch={BATCH_ROOTS}")

    def _submit():
        return session.submit(sql, roots)

    us_warm = time_call(_submit, repeat=repeat)
    out["warm"] = us_warm
    st = session.stats
    emit(f"exp_serving/cached_dispatch/d{depth}", us_warm / BATCH_ROOTS,
         f"total_us={us_warm:.1f},plan_hits={st['plan_hits']},"
         f"plan_misses={st['plan_misses']},"
         f"cold_over_warm={us_cold / max(us_warm, 1e-9):.1f}x")

    # same chosen plan, one root at a time (the serving alternative)
    choice = session.plan_for(sql, roots).choice

    def _sequential():
        return [run_query(choice.query, ds, r) for r in roots]

    us_seq = time_call(_sequential, repeat=repeat)
    out["seq"] = us_seq
    # PAIRED like every other gated ratio (calls interleaved so shared-host
    # drift cancels): unpaired, this cell flipped under 1.0 on machine
    # weather while the code was byte-identical
    speedup = time_ratio(_sequential, _submit, repeat=max(repeat, 7))
    emit(f"exp_serving/bucketed_vs_sequential/d{depth}",
         us_warm / BATCH_ROOTS,
         f"per_root_speedup_vs_sequential={speedup:.2f}")

    # -- observability gate: a disabled tracer must cost nothing ----------
    # paired ratio (no tracer) / (disabled tracer installed): the disabled
    # path in submit/_execute is one attribute read + a None check per
    # seam, so this must sit at ~1.0 (gated >= 0.95 in scripts/perf_gate)
    from repro.obs import Tracer


    disabled = Tracer(enabled=False)

    def _submit_no_tracer():
        session.tracer = None
        return session.submit(sql, roots)

    def _submit_disabled_tracer():
        session.tracer = disabled
        return session.submit(sql, roots)

    tracer_ratio = time_ratio(_submit_no_tracer, _submit_disabled_tracer,
                              repeat=max(repeat, 7))
    session.tracer = None
    out["disabled_tracer_ratio"] = tracer_ratio
    emit(f"exp_serving/disabled_tracer_ratio/d{depth}",
         us_warm / BATCH_ROOTS,
         f"disabled_tracer_ratio={tracer_ratio:.3f}")

    # -- admission gate: the guard ladder must be ~free on admitted traffic
    # paired ratio (guards off) / (guards on) over all-traverse traffic:
    # the ladder is one O(1) degree lookup + a few float ops per root, so
    # this must sit at ~1.0 (gated >= 0.95 in scripts/perf_gate)
    unguarded = ServingSession(ds, guards=False)
    unguarded.submit(sql, roots)    # warm its plan cache + jit

    def _submit_unguarded():
        return unguarded.submit(sql, roots)

    admission_ratio = time_ratio(_submit_unguarded, _submit,
                                 repeat=max(repeat, 7))
    out["admission_overhead_ratio"] = admission_ratio
    emit(f"exp_serving/admission_overhead_ratio/d{depth}",
         us_warm / BATCH_ROOTS,
         f"admission_overhead_ratio={admission_ratio:.3f},"
         f"admitted={session.stats['admission_traverse']}")

    # -- admission payoff (informational, ungated): degrading the hub ----
    # tighten the degrade budget so the HUB root depth-clamps while the
    # leaf-ish roots still traverse; per-request p99 over the mixed batch
    # should drop vs. the unguarded session answering the same traffic
    from repro.planner.calibrate import Calibrator
    from repro.planner.guards import admit_roots, guard_cost_us

    hub = admit_roots(ds, "outbound", roots, depth,
                      session.calibrator.constants)[0]
    lo = guard_cost_us(hub.estimate, session.calibrator.constants, depth=1)
    tight = session.calibrator.constants._replace(
        guard_degrade_us=(lo + hub.est_us) / 2.0)
    guarded = ServingSession(ds, calibrator=Calibrator(prior=tight))
    guarded.submit(sql, roots)      # warm (root 0 degrades here)
    degraded = [r for r, _ in guarded.last_report.degraded_roots]

    def _p99(s):
        ts = []
        for _ in range(max(repeat * 4, 20)):
            t0 = time.perf_counter()
            jax.block_until_ready(
                [r.count for r in s.submit(sql, roots)])
            ts.append((time.perf_counter() - t0) * 1e6)
        return float(np.percentile(ts, 99))

    p99_g, p99_u = _p99(guarded), _p99(unguarded)
    out["guarded_p99_ratio"] = p99_g / max(p99_u, 1e-9)
    emit(f"exp_serving/guarded_p99_vs_unguarded/d{depth}", p99_g,
         f"guarded_p99_vs_unguarded={p99_g / max(p99_u, 1e-9):.2f},"
         f"unguarded_p99_us={p99_u:.1f},degraded_roots={len(degraded)}")

    # -- calibration gate: refit constants must not worsen selection ------
    cal = session.calibrator
    consts = cal.refit()
    caps = choice.query.caps
    cal_report = plan(sql, ds, caps=caps, constants=consts)
    forced = {c.label: time_call(run_query, c.query, ds, 0, repeat=repeat)
              for c in cal_report.ranked if not c.use_kernel}
    best_forced = min(forced, key=forced.get)
    us_cal = forced[cal_report.best.label]
    if cal_report.best.label == best_forced:
        regret = 1.0
    else:
        # paired measurement for the GATED ratio (see exp_planner): two
        # near-tied engines timed seconds apart would flip this cell on
        # shared-host noise alone
        q_best = next(c.query for c in cal_report.ranked
                      if c.label == best_forced)
        regret = time_ratio(
            lambda: run_query(cal_report.best.query, ds, 0),
            lambda: run_query(q_best, ds, 0), repeat=max(repeat, 7))
    out["calibrated_regret"] = regret
    emit(f"exp_serving/calibrated_regret/d{depth}", us_cal,
         f"chose={cal_report.best.label},best_forced={best_forced},"
         f"calibrated_vs_best_forced={regret:.2f},"
         f"observations={cal.count},refits={cal.refits}")

    # -- bit-parallel coalescing gate: 32 lanes of one frontier word ------
    # the coalesced side answers MQ_BATCH single-root requests with one
    # flush (its multi-lane buckets plan multiquery: one word sweep per
    # level for every lane, at the buckets' right-sized caps); the
    # baseline is the same roots and bucket layout through the
    # one-root-per-vmap-lane bucketed executor with the shape-level
    # chosen engine
    from repro.core.engine import WORD_LANES, run_query_buckets

    mq_roots = list(range(WORD_LANES))
    mq_entry = session.plan_for(sql, mq_roots)

    def _coalesced():
        tickets = [session.enqueue(sql, r) for r in mq_roots]
        session.flush()
        return [t.result() for t in tickets]

    def _bucketed_vmap():
        return run_query_buckets(choice.query, ds, mq_entry.buckets)

    mq_res = _coalesced()         # also compiles the coalesced dispatches
    seq_res = _bucketed_vmap()
    mq_match = all(_row_set(a) == _row_set(b)
                   for a, b in zip(mq_res, seq_res))
    if not mq_match:
        raise RuntimeError(
            "multiquery_throughput: the coalesced bit-parallel results "
            "diverged from the bucketed per-root baseline — the ratio "
            "below would compare different answers")
    us_mq = time_call(_coalesced, repeat=repeat)
    mq_ratio = time_ratio(_bucketed_vmap, _coalesced,
                          repeat=max(repeat, 7))
    mq_engines = ",".join(sorted({c.label
                                  for c in mq_entry.bucket_choices}))
    out["multiquery_ratio"] = mq_ratio
    emit(f"exp_serving/multiquery_throughput/d{depth}",
         us_mq / WORD_LANES,
         f"multiquery_vs_bucketed={mq_ratio:.2f},batch={WORD_LANES},"
         f"rows_match={int(mq_match)},engines={mq_engines}")

    # -- plan-store gate: rehydrated serving must match cold results ------
    cold_res = session.submit(sql, roots)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "plan_store.json")
        session.save_plan_store(path)
        warm = ServingSession(ds, plan_store=path)
        t0 = time.perf_counter()
        warm_res = warm.submit(sql, roots)
        jax.block_until_ready([r.count for r in warm_res])
        us_rehydrated = (time.perf_counter() - t0) * 1e6
    match = all(_row_set(a) == _row_set(b)
                for a, b in zip(cold_res, warm_res))
    planning = sum(warm.counters.values())
    out["rehydrated_match"] = match
    emit(f"exp_serving/rehydrated_serving/d{depth}", us_rehydrated,
         f"rehydrated_match={int(match)},planning_calls={planning},"
         f"first_request_vs_cold_plan="
         f"{us_rehydrated / max(us_cold, 1e-9):.2f}")
    return out


if __name__ == "__main__":
    run()
