"""Serving experiment: cached-plan dispatch latency for the traversal
serving layer (beyond-paper; the ROADMAP's many-users north star).

Three cells:

* ``exp_serving/cold_plan`` — the FIRST request for a query shape: parse +
  statistics + costing + bucket layout + jit compiles.  Paid once per
  (shape, bucket signature).
* ``exp_serving/cached_dispatch`` — steady state: every request after the
  first hits the plan cache and the warm jitted dispatches; this is the
  number a serving SLO is written against.
* ``exp_serving/bucketed_vs_sequential`` — the reach-bucketed batch against
  a Python loop of single-root queries through the same chosen plan (the
  exp1 regression cell, measured at the serving layer).
"""
from __future__ import annotations

import time

import jax

from repro.core.engine import run_query
from repro.planner import ServingSession, paper_listing

from .bench_util import emit, time_call, tree_dataset

BATCH_ROOTS = 8


def run(num_vertices: int = 200_000, height: int = 60, depth: int = 5,
        repeat: int = 5) -> dict:
    ds = tree_dataset(num_vertices, height, payload_cols=0)
    sql = paper_listing(1, root=0, depth=depth)
    # a served batch mixes the hub root with leaf-ish roots — the regime
    # where lockstep batching regressed and bucketing pays
    roots = list(range(BATCH_ROOTS))
    out = {}

    session = ServingSession(ds)
    t0 = time.perf_counter()
    jax.block_until_ready([r.count for r in session.submit(sql, roots)])
    us_cold = (time.perf_counter() - t0) * 1e6
    out["cold"] = us_cold
    emit(f"exp_serving/cold_plan/d{depth}", us_cold,
         f"plans+compile,batch={BATCH_ROOTS}")

    def _submit():
        return session.submit(sql, roots)

    us_warm = time_call(_submit, repeat=repeat)
    out["warm"] = us_warm
    st = session.stats
    emit(f"exp_serving/cached_dispatch/d{depth}", us_warm / BATCH_ROOTS,
         f"total_us={us_warm:.1f},plan_hits={st['plan_hits']},"
         f"plan_misses={st['plan_misses']},"
         f"cold_over_warm={us_cold / max(us_warm, 1e-9):.1f}x")

    # same chosen plan, one root at a time (the serving alternative)
    choice = session.plan_for(sql, roots).choice

    def _sequential():
        return [run_query(choice.query, ds, r) for r in roots]

    us_seq = time_call(_sequential, repeat=repeat)
    out["seq"] = us_seq
    emit(f"exp_serving/bucketed_vs_sequential/d{depth}",
         us_warm / BATCH_ROOTS,
         f"per_root_speedup_vs_sequential="
         f"{us_seq / max(us_warm, 1e-9):.2f}")
    return out


if __name__ == "__main__":
    run()
