"""Experiment 3 (paper Fig. 7): the slim-CTE + top-level-join rewriting.

The recursion carries only (id, to); payload columns are joined back once
at the top.  Paper findings to reproduce:
  * TRecursive benefits (~3x over the row-store): unnecessary columns are
    materialized once, at the very end;
  * the rewrite does NOT rescue the row-store (rows are re-read whole);
  * PRecursive is unaffected (it already materializes late).
"""
from __future__ import annotations

from repro.core import EngineCaps
from repro.core.engine import RecursiveQuery, run_query

from .bench_util import emit, level_caps, time_call, tree_dataset

ENGINES = ("precursive", "trecursive_rewrite", "rowstore_rewrite",
           "rowstore_index_rewrite")


def run(num_vertices: int = 100_000, height: int = 60,
        depths=(5, 10, 20), payloads=(8, 16), repeat: int = 3) -> dict:
    out = {}
    for n in payloads:
        ds = tree_dataset(num_vertices, height, payload_cols=n)
        caps = level_caps(num_vertices, height)
        for depth in depths:
            for eng in ENGINES:
                q = RecursiveQuery(engine=eng, max_depth=depth,
                                   payload_cols=n, caps=caps)
                us = time_call(run_query, q, ds, 0, repeat=repeat)
                out[(eng, n, depth)] = us
            for eng in ENGINES:
                us = out[(eng, n, depth)]
                sp = out[("rowstore_rewrite", n, depth)] / us
                emit(f"exp3/{eng}/N{n}/d{depth}", us,
                     f"speedup_vs_rowstore_rewrite={sp:.2f}")
    return out


if __name__ == "__main__":
    run()
