"""Experiment 1 (paper Fig. 5): BFS with only traversal columns.

Table = (id, from, to, name): no payload, so late materialization has the
least to win — the paper found PRecursive still ahead (2 of 4 attribute
streams touched per level) and TRecursive ~= PostgreSQL.
Engines: the paper's four + the beyond-paper bitmap/hybrid engines.

Beyond the paper, a batched-roots cell times the serving path: ONE
vmap-batched dispatch answering ``BATCH_ROOTS`` users' traversals at once,
reported as us-per-root against the sequential loop.
"""
from __future__ import annotations

from repro.core import EngineCaps
from repro.core.engine import RecursiveQuery, run_query, run_query_batch

from .bench_util import emit, level_caps, time_call, tree_dataset

ENGINES = ("precursive", "trecursive", "rowstore", "rowstore_index",
           "bitmap", "hybrid")

BATCH_ROOTS = 8


def run(num_vertices: int = 200_000, height: int = 60,
        depths=(5, 10, 20), repeat: int = 5) -> dict:
    ds = tree_dataset(num_vertices, height, payload_cols=0)
    caps = level_caps(num_vertices, height)
    out = {}
    for depth in depths:
        for eng in ENGINES:
            q = RecursiveQuery(engine=eng, max_depth=depth, payload_cols=0,
                               caps=caps)
            us = time_call(run_query, q, ds, 0, repeat=repeat)
            out[(eng, depth)] = us
        for eng in ENGINES:
            us = out[(eng, depth)]
            speedup = out[("rowstore", depth)] / us
            emit(f"exp1/{eng}/d{depth}", us,
                 f"speedup_vs_rowstore={speedup:.2f}")

    # batched multi-root serving cell: one dispatch, BATCH_ROOTS roots
    roots = list(range(BATCH_ROOTS))
    depth = depths[0]
    q = RecursiveQuery(engine="precursive", max_depth=depth, payload_cols=0,
                       caps=caps)

    def _sequential():
        return [run_query(q, ds, r) for r in roots]

    us_seq = time_call(_sequential, repeat=repeat)
    us_batch = time_call(run_query_batch, q, ds, roots, repeat=repeat)
    out[("batch", depth)] = us_batch
    emit(f"exp1/precursive_batch{BATCH_ROOTS}/d{depth}",
         us_batch / BATCH_ROOTS,
         f"per_root_speedup_vs_sequential="
         f"{us_seq / max(us_batch, 1e-9):.2f}")
    return out


if __name__ == "__main__":
    run()
