"""Experiment 1 (paper Fig. 5): BFS with only traversal columns.

Table = (id, from, to, name): no payload, so late materialization has the
least to win — the paper found PRecursive still ahead (2 of 4 attribute
streams touched per level) and TRecursive ~= PostgreSQL.
Engines: the paper's four + the beyond-paper bitmap/hybrid engines.

Beyond the paper, batched-roots cells time the serving path answering
``BATCH_ROOTS`` users' traversals at once:

* ``precursive_batch*`` — the REACH-BUCKETED path (one jitted dispatch per
  predicted-reach bucket, per-bucket caps), the production serving path;
* ``precursive_batch*_lockstep`` — the old single worst-case vmap dispatch,
  kept as the regression reference.

Both are warmed up exactly like the sequential baseline (``time_call``
discards ``warmup`` compile-laden calls for every variant), and both the
per-root and the whole-batch wall time are reported, so
``per_root_speedup_vs_sequential`` measures steady-state serving, not
first-call tracing.
"""
from __future__ import annotations

from repro.core import EngineCaps
from repro.core.engine import (RecursiveQuery, run_query, run_query_batch,
                               run_query_buckets)

from .bench_util import (emit, level_caps, time_call, time_ratio,
                         tree_dataset)

ENGINES = ("precursive", "trecursive", "rowstore", "rowstore_index",
           "bitmap", "hybrid")
# the direction-optimizing engines, gated against the best PUSH-ONLY cell
# (every engine above pushes from the frontier; diropt may pull)
DIROPT_ENGINES = ("diropt", "diropt_hybrid")

BATCH_ROOTS = 8


def run(num_vertices: int = 200_000, height: int = 60,
        depths=(5, 10, 20), repeat: int = 5) -> dict:
    ds = tree_dataset(num_vertices, height, payload_cols=0)
    caps = level_caps(num_vertices, height)
    out = {}
    for depth in depths:
        for eng in ENGINES + DIROPT_ENGINES:
            q = RecursiveQuery(engine=eng, max_depth=depth, payload_cols=0,
                               caps=caps)
            us = time_call(run_query, q, ds, 0, repeat=repeat)
            out[(eng, depth)] = us
        best_push_eng = min(ENGINES, key=lambda e: out[(e, depth)])
        for eng in ENGINES:
            us = out[(eng, depth)]
            speedup = out[("rowstore", depth)] / us
            emit(f"exp1/{eng}/d{depth}", us,
                 f"speedup_vs_rowstore={speedup:.2f}")
        qp = RecursiveQuery(engine=best_push_eng, max_depth=depth,
                            payload_cols=0, caps=caps)
        for eng in DIROPT_ENGINES:
            us = out[(eng, depth)]
            # the gated ratio is measured PAIRED (push and diropt calls
            # interleaved): on a noisy shared host the quotient of two
            # medians taken seconds apart can swing +-30%, which would
            # gate on machine weather, not on the engines
            qd = RecursiveQuery(engine=eng, max_depth=depth,
                                payload_cols=0, caps=caps)
            ratio = time_ratio(lambda: run_query(qp, ds, 0),
                               lambda: run_query(qd, ds, 0),
                               repeat=max(repeat, 9))
            # informational keys (like the lockstep reference cell): the
            # paper's exp1 TREE has E == V-1, where deferred emission's
            # saved O(E) writes wash against the O(V) depth bookkeeping —
            # diropt is push-PARITY here by construction (~1.0x), and
            # gating a statistical tie would fail CI on machine weather.
            # The GATED `diropt_vs_push_only` cell lives on the
            # wide-frontier regime (E > V) in exp_direction/diropt_wide.
            key = (f"diropt_vs_push_only_d{depth}" if eng == "diropt"
                   else f"{eng}_vs_push_only")
            emit(f"exp1/{eng}/d{depth}", us,
                 f"{key}={ratio:.2f},push_only={best_push_eng},"
                 f"speedup_vs_rowstore="
                 f"{out[('rowstore', depth)] / max(us, 1e-9):.2f}")

    # batched multi-root serving cells: BATCH_ROOTS roots per request
    from repro.planner.optimize import bucket_roots

    roots = list(range(BATCH_ROOTS))
    depth = depths[0]
    q = RecursiveQuery(engine="precursive", max_depth=depth, payload_cols=0,
                       caps=caps)
    buckets = bucket_roots(ds, roots, direction=q.direction,
                           max_depth=depth, dedup=q.dedup, caps=caps)

    def _sequential():
        return [run_query(q, ds, r) for r in roots]

    us_seq = time_call(_sequential, repeat=repeat)
    us_buck = time_call(run_query_buckets, q, ds, buckets, repeat=repeat)
    us_lock = time_call(run_query_batch, q, ds, roots, repeat=repeat)
    out[("batch", depth)] = us_buck
    emit(f"exp1/precursive_batch{BATCH_ROOTS}/d{depth}",
         us_buck / BATCH_ROOTS,
         f"per_root_speedup_vs_sequential="
         f"{us_seq / max(us_buck, 1e-9):.2f},"
         f"total_us={us_buck:.1f},buckets={len(buckets)}")
    emit(f"exp1/precursive_batch{BATCH_ROOTS}_lockstep/d{depth}",
         us_lock / BATCH_ROOTS,
         f"lockstep_vs_sequential={us_seq / max(us_lock, 1e-9):.2f},"
         f"total_us={us_lock:.1f}")
    return out


if __name__ == "__main__":
    run()
