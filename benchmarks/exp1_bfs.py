"""Experiment 1 (paper Fig. 5): BFS with only traversal columns.

Table = (id, from, to, name): no payload, so late materialization has the
least to win — the paper found PRecursive still ahead (2 of 4 attribute
streams touched per level) and TRecursive ~= PostgreSQL.
Engines: the paper's four + the beyond-paper bitmap/hybrid engines.
"""
from __future__ import annotations

from repro.core import EngineCaps
from repro.core.engine import RecursiveQuery, run_query

from .bench_util import emit, level_caps, time_call, tree_dataset

ENGINES = ("precursive", "trecursive", "rowstore", "rowstore_index",
           "bitmap", "hybrid")


def run(num_vertices: int = 200_000, height: int = 60,
        depths=(5, 10, 20), repeat: int = 5) -> dict:
    ds = tree_dataset(num_vertices, height, payload_cols=0)
    caps = level_caps(num_vertices, height)
    out = {}
    for depth in depths:
        base = None
        for eng in ENGINES:
            q = RecursiveQuery(engine=eng, max_depth=depth, payload_cols=0,
                               caps=caps)
            us = time_call(run_query, q, ds, 0, repeat=repeat)
            if eng == "rowstore":
                base = us
            out[(eng, depth)] = us
        for eng in ENGINES:
            us = out[(eng, depth)]
            speedup = out[("rowstore", depth)] / us
            emit(f"exp1/{eng}/d{depth}", us,
                 f"speedup_vs_rowstore={speedup:.2f}")
    return out


if __name__ == "__main__":
    run()
