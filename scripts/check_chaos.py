"""CI chaos smoke: one injected fault per class through the serving front
door, on a small graph, in one process.

This is NOT the full chaos suite (``tests/test_chaos.py`` is tier-1); it
is the fast end-to-end sanity pass ``scripts/check.sh`` runs after the
benchmarks: arm each :mod:`repro.obs.faultinject` point once (plus the two
no-seam fault classes: garbage roots and an over-budget root), drive a
request through it, and print one PASS/FAIL line per class.  Exit 1 if
any class fails — a fault must end in a classified degraded answer or a
typed error, never a crash, a hang, or silently-wrong rows.

Usage: PYTHONPATH=src python scripts/check_chaos.py
"""
from __future__ import annotations

import sys
import warnings

sys.path.insert(0, "src")


def main() -> int:
    import numpy as np

    from repro.core.engine import Dataset
    from repro.data.treegen import TreeSpec, make_edge_table
    from repro.obs import faultinject
    from repro.planner import ServingSession, paper_listing
    from repro.planner.calibrate import Calibrator
    from repro.planner.cost import DEFAULT_CONSTANTS
    from repro.planner.guards import AdmissionError, InvalidRequestError
    from repro.planner.plan_store import save_session

    spec = TreeSpec(num_vertices=2000, height=8, payload_cols=0, seed=7)
    ds = Dataset.prepare(make_edge_table(spec), spec.num_vertices)
    sql = paper_listing(1, root=0, depth=4)
    roots = [0, 1, 7, 500]

    baseline_session = ServingSession(ds)
    baseline = baseline_session.submit(sql, roots)
    base_ids = [sorted(np.asarray(r.values["id"])[:int(r.count)].tolist())
                for r in baseline]

    def parity(out, skip=()):
        for r, got, want in zip(roots, out, base_ids):
            if r in skip:
                continue
            ids = sorted(
                np.asarray(got.values["id"])[:int(got.count)].tolist())
            if ids != want:
                return False
        return True

    results = []

    def check(name, fn):
        try:
            ok, detail = fn()
        except Exception as e:                     # a crash IS the failure
            ok, detail = False, f"crashed: {type(e).__name__}: {e}"
        results.append((name, ok, detail))
        print(f"{'PASS' if ok else 'FAIL'} chaos/{name}: {detail}")

    def overflow():
        s = ServingSession(ds)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            with faultinject.injected("bucket_overflow"):
                out = s.submit(sql, roots)
        rep = s.last_report
        return (rep.retries >= 1 and parity(out),
                f"retries={rep.retries}, rows match baseline")

    def straggler():
        s = ServingSession(ds)
        s.submit(sql, roots)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            with faultinject.injected("straggler_sleep", 0.05, times=None):
                out = s.submit(sql, roots, deadline_us=20_000.0)
        rep = s.last_report
        return (rep.truncated and parity(out, skip=set(rep.skipped_roots)),
                f"truncated, skipped_roots={rep.skipped_roots}")

    def corrupt_store(tmpdir=[]):
        import os
        import tempfile
        d = tempfile.mkdtemp(prefix="chaos_store.")
        path = os.path.join(d, "store.json")
        save_session(baseline_session, path)
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            with faultinject.injected("plan_store_corrupt"):
                s = ServingSession(ds, plan_store=path)
        warned = any("cold-start" in str(x.message) for x in w)
        out = s.submit(sql, roots)
        return (warned and parity(out),
                "warned + cold-started + serves row-parity answers")

    def poison():
        import math
        s = ServingSession(ds, calibrate_every=4)
        s.submit(sql, roots)         # cold: plan + compile, no observation
        with faultinject.injected("calibrator_poison", float("nan"),
                                  times=None):
            out = s.submit(sql, roots)
        c = s.calibrator.constants
        finite = all(v is None or math.isfinite(v)
                     for v in (c.base_us, c.level_us, c.bytes_per_us,
                               c.kernel_factor))
        return (s.calibrator.discarded > 0 and finite and parity(out),
                f"discarded={s.calibrator.discarded}, constants finite")

    def garbage():
        s = ServingSession(ds)
        typed = 0
        for bad in ([-1], [ds.num_vertices + 5], [0.25]):
            try:
                s.submit(sql, bad)
            except InvalidRequestError:
                typed += 1
        tight = DEFAULT_CONSTANTS._replace(guard_degrade_us=1e-6,
                                           guard_reject_us=1e-3)
        s2 = ServingSession(ds, calibrator=Calibrator(prior=tight))
        try:
            s2.submit(sql, [0])
        except AdmissionError:
            typed += 1
        out = s.submit(sql, roots)                 # the session survives
        return (typed == 4 and parity(out),
                f"{typed}/4 typed errors, session still serves")

    check("bucket_overflow", overflow)
    check("straggler_deadline", straggler)
    check("plan_store_corrupt", corrupt_store)
    check("calibrator_poison", poison)
    check("garbage_requests", garbage)

    if faultinject.armed():
        print("FAIL chaos/seam: a fault is still armed after the sweep")
        return 1
    failed = [n for n, ok, _ in results if not ok]
    if failed:
        print(f"CHAOS SMOKE FAILED: {failed}")
        return 1
    print(f"chaos smoke OK: {len(results)} fault class(es)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
