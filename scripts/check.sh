#!/usr/bin/env bash
# CI gate: tier-1 tests + the quick benchmark profile + the perf gate +
# the observability trace smoke.
#
#   scripts/check.sh
#
# Fails if any tier-1 test fails (pytest -x aborts on the first regression),
# if the quick benchmark run cannot complete, if the perf gate trips (the
# batched serving cell must report per_root_speedup_vs_sequential >= 1.0,
# every planner cell must keep its selection regret vs_best_forced <= 1.2,
# serving with a DISABLED tracer must stay within 5% of no tracer at
# all, and the admission guard ladder must stay within 5% of guards-off
# serving — see scripts/perf_gate.py), if the trace smoke produces an
# invalid trace (a tiny traversal-serving run with --trace on, validated
# by scripts/check_trace.py: header, span fields, id/parent forest, time
# nesting), or if the chaos smoke fails (one injected fault per class
# through the serving front door — scripts/check_chaos.py; every fault
# must end in a classified degraded answer or a typed error, never a
# crash or silently-wrong rows).  Writes BENCH_bfs.json (with a _meta
# provenance stamp) and
# appends one line to BENCH_history.jsonl so the perf trajectory can be
# compared across PRs; the perf gate prints a NON-GATING drift report
# against that history.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== dev deps (hypothesis: property suites) =="
# hit the network only when hypothesis is actually missing; on failure the
# property suites still RUN on the vendored fallback engine
python -c "import hypothesis" 2>/dev/null \
  || python -m pip install -q -r requirements-dev.txt \
  || echo "WARNING: pip install failed (offline?); property suites run" \
          "on the vendored fallback engine (tests/_hypothesis_fallback.py)"

echo "== tier-1 pytest (sharded) =="
# Sharded into NSHARDS pytest processes: one long-lived process
# accumulates enough XLA compilation state that the native
# backend_compile segfaults late in the suite on some hosts.  Several
# smaller processes keep every test running while bounding per-process
# compile-cache growth; the split is alphabetical (stable as files are
# added), contiguous, non-overlapping and exhaustive by construction.
# (4 since the multiquery suite landed: at 3 the shard holding the
# planner+property+serving block crossed the compile-state limit again.)
NSHARDS=4
mapfile -t TIER1_FILES < <(ls tests/test_*.py | sort)
total=${#TIER1_FILES[@]}
per=$(( (total + NSHARDS - 1) / NSHARDS ))
for (( start=0; start<total; start+=per )); do
  python -m pytest -x -q "${TIER1_FILES[@]:start:per}"
done

echo "== quick benchmarks -> BENCH_bfs.json (+ BENCH_history.jsonl) =="
python -m benchmarks.run --quick --json BENCH_bfs.json \
  --history BENCH_history.jsonl \
  --timestamp "$(date -u +%Y-%m-%dT%H:%M:%SZ)" "$@"

echo "== perf gate (+ drift report vs history) =="
python scripts/perf_gate.py BENCH_bfs.json --history BENCH_history.jsonl

echo "== trace smoke =="
TRACE_TMP="$(mktemp -t trace_smoke.XXXXXX.jsonl)"
trap 'rm -f "$TRACE_TMP"' EXIT
python -m repro.launch.serve --traversal --vertices 2000 --height 8 \
  --batch 4 --requests 3 --depth 4 --trace "$TRACE_TMP" > /dev/null
python scripts/check_trace.py "$TRACE_TMP" --min-spans 5

echo "== chaos smoke =="
python scripts/check_chaos.py
