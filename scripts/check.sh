#!/usr/bin/env bash
# CI gate: tier-1 tests + the quick benchmark profile.
#
#   scripts/check.sh
#
# Fails if any tier-1 test fails (pytest -x aborts on the first regression)
# or if the quick benchmark run cannot complete; writes BENCH_bfs.json so
# the perf trajectory (incl. the planner's vs_best_forced regret per cell)
# can be compared across PRs.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 pytest =="
python -m pytest -x -q

echo "== quick benchmarks -> BENCH_bfs.json =="
python -m benchmarks.run --quick --json BENCH_bfs.json "$@"
