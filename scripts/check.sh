#!/usr/bin/env bash
# CI gate: tier-1 tests + the quick benchmark profile + the perf gate.
#
#   scripts/check.sh
#
# Fails if any tier-1 test fails (pytest -x aborts on the first regression),
# if the quick benchmark run cannot complete, or if the perf gate trips:
# the batched serving cell must report per_root_speedup_vs_sequential >= 1.0
# and every planner cell must keep its selection regret vs_best_forced
# <= 1.2 (see scripts/perf_gate.py).  Writes BENCH_bfs.json so the perf
# trajectory can be compared across PRs.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== dev deps (hypothesis: property suites) =="
# hit the network only when hypothesis is actually missing; on failure the
# property suites still RUN on the vendored fallback engine
python -c "import hypothesis" 2>/dev/null \
  || python -m pip install -q -r requirements-dev.txt \
  || echo "WARNING: pip install failed (offline?); property suites run" \
          "on the vendored fallback engine (tests/_hypothesis_fallback.py)"

echo "== tier-1 pytest =="
python -m pytest -x -q

echo "== quick benchmarks -> BENCH_bfs.json =="
python -m benchmarks.run --quick --json BENCH_bfs.json "$@"

echo "== perf gate =="
python scripts/perf_gate.py BENCH_bfs.json
