"""CI perf gate over the quick-bench artifact (BENCH_bfs.json).

Fails (exit 1) when the perf trajectory regresses past the ROADMAP bars:

* any cell reporting ``per_root_speedup_vs_sequential`` below 1.0 — the
  batched serving path must beat a sequential loop per root (this cell was
  0.41 before reach bucketing; the gate keeps it from regressing);
* any planner cell reporting ``vs_best_forced`` above 1.2 — the planner's
  selection regret bar;
* the calibration gate: any cell reporting ``calibrated_vs_best_forced``
  above the same 1.2 bar — REFIT cost constants (the serving feedback
  loop, ``exp_serving/calibrated_regret``) must not make engine selection
  worse than the bar the hand-calibrated prior meets;
* the plan-store gate: any cell reporting ``rehydrated_match`` other than
  1 — a session rehydrated from a plan store must produce row-identical
  results to the cold-planned session (``exp_serving/rehydrated_serving``);
* the direction-optimizing gate: any cell reporting
  ``diropt_vs_push_only`` below 1.0 — the per-level push/pull switching
  engine must not lose to the best static push engine on the
  wide-frontier quick cell (``exp_direction/diropt_wide/d8``: a dense
  E > V graph, the regime the optimization targets; the ratio is
  measured PAIRED so shared-host drift cancels).  The exp1 tree cells
  (``exp1/diropt/d{4,8}``) report under ``diropt_vs_push_only_d{D}``
  (informational, ungated): on a tree E == V-1 and diropt is
  push-parity by construction — gating a statistical tie would fail CI
  on machine weather.  The hybrid variant likewise reports under
  ``diropt_hybrid_vs_push_only``.
* the observability gate: any cell reporting ``disabled_tracer_ratio``
  below 0.95 — serving with a DISABLED tracer installed must be as fast
  as serving with no tracer at all (paired ratio,
  ``exp_serving/disabled_tracer_ratio``); tracing is wired into the
  production seams only because the off path is free.
* the weighted gate: any cell reporting ``sssp_bucketed_vs_lockstep``
  below 1.0 — the delta-stepping-style reach-bucketed SSSP batch
  (``exp_weighted/sssp_bucketed/d8``) must not lose to one lockstep
  batched dispatch at the global caps (paired ratio; the bucketing
  machinery is shared with reach serving, so a regression here means the
  value plane broke the bucket path's economics).
* the bit-parallel coalescing gate: any cell reporting
  ``multiquery_vs_bucketed`` below 4.0 — 32 coalesced single-root
  requests answered through the packed-word multiquery engine
  (``exp_serving/multiquery_throughput``: one uint32 frontier word, one
  MS-BFS sweep per level for all 32 lanes) must beat the
  one-root-per-vmap-lane bucketed path by at least 4x (paired ratio; the
  cell itself verifies row-set parity before timing).
* the admission gate: any cell reporting ``admission_overhead_ratio``
  below 0.95 — the guard ladder (``exp_serving/admission_overhead_ratio``:
  guards off vs. the default guarded front door, paired, on all-admitted
  traffic) must be ~free, or admission control cannot be left on by
  default.  The payoff cell (``guarded_p99_vs_unguarded``) is
  informational and ungated.

The lockstep reference cell deliberately reports its ratio under a
different key (``lockstep_vs_sequential``) so the gate does not fire on the
kept-for-comparison regression baseline.

With ``--history BENCH_history.jsonl`` (or when the default history file
exists) the gate additionally prints a NON-GATING drift report: the
current artifact's ``us_per_call`` cells against the median of the last
few history entries.  Absolute timings vary run to run and host to host,
so drift never fails the gate — it exists so a slow creep is VISIBLE in CI
logs before it trips a gated ratio.

Usage: python scripts/perf_gate.py [BENCH_bfs.json] [--history PATH]
"""
from __future__ import annotations

import json
import os
import re
import sys

SPEEDUP_RE = re.compile(r"(?:^|,)per_root_speedup_vs_sequential=([\d.]+)")
REGRET_RE = re.compile(r"(?:^|,)vs_best_forced=([\d.]+)")
CAL_REGRET_RE = re.compile(r"(?:^|,)calibrated_vs_best_forced=([\d.]+)")
REHYDRATED_RE = re.compile(r"(?:^|,)rehydrated_match=(\d+)")
DIROPT_RE = re.compile(r"(?:^|,)diropt_vs_push_only=([\d.]+)")
TRACER_RE = re.compile(r"(?:^|,)disabled_tracer_ratio=([\d.]+)")
SSSP_RE = re.compile(r"(?:^|,)sssp_bucketed_vs_lockstep=([\d.]+)")
MULTIQUERY_RE = re.compile(r"(?:^|,)multiquery_vs_bucketed=([\d.]+)")
ADMISSION_RE = re.compile(r"(?:^|,)admission_overhead_ratio=([\d.]+)")

MIN_PER_ROOT_SPEEDUP = 1.0
MAX_PLANNER_REGRET = 1.2
MIN_DIROPT_SPEEDUP = 1.0
MIN_TRACER_RATIO = 0.95
MIN_SSSP_SPEEDUP = 1.0
MIN_MULTIQUERY_SPEEDUP = 4.0
MIN_ADMISSION_RATIO = 0.95

# drift-report knobs (non-gating): compare against the median of the last
# HISTORY_WINDOW runs, flag cells that moved more than DRIFT_FLAG x
HISTORY_WINDOW = 5
DRIFT_FLAG = 1.5

GATES = (SPEEDUP_RE, REGRET_RE, CAL_REGRET_RE, REHYDRATED_RE, DIROPT_RE,
         TRACER_RE, SSSP_RE, MULTIQUERY_RE, ADMISSION_RE)


def bench_rows(doc: dict) -> dict:
    """The benchmark cells of an artifact: every key except the ``_meta``
    provenance stamp (and any future ``_``-prefixed sidecar)."""
    return {k: v for k, v in doc.items() if not k.startswith("_")}


def check(rows: dict) -> list[str]:
    failures = []
    for name, row in sorted(bench_rows(rows).items()):
        derived = row.get("derived", "")
        m = SPEEDUP_RE.search(derived)
        if m and float(m.group(1)) < MIN_PER_ROOT_SPEEDUP:
            failures.append(
                f"{name}: per_root_speedup_vs_sequential={m.group(1)} "
                f"< {MIN_PER_ROOT_SPEEDUP} (batched serving must beat "
                "the sequential loop)")
        m = REGRET_RE.search(derived)
        if m and float(m.group(1)) > MAX_PLANNER_REGRET:
            failures.append(
                f"{name}: vs_best_forced={m.group(1)} > "
                f"{MAX_PLANNER_REGRET} (planner selection regret bar)")
        m = CAL_REGRET_RE.search(derived)
        if m and float(m.group(1)) > MAX_PLANNER_REGRET:
            failures.append(
                f"{name}: calibrated_vs_best_forced={m.group(1)} > "
                f"{MAX_PLANNER_REGRET} (refit constants must not worsen "
                "planner regret)")
        m = REHYDRATED_RE.search(derived)
        if m and int(m.group(1)) != 1:
            failures.append(
                f"{name}: rehydrated_match={m.group(1)} != 1 "
                "(plan-store-rehydrated serving must match cold-plan "
                "results)")
        m = DIROPT_RE.search(derived)
        if m and float(m.group(1)) < MIN_DIROPT_SPEEDUP:
            failures.append(
                f"{name}: diropt_vs_push_only={m.group(1)} < "
                f"{MIN_DIROPT_SPEEDUP} (direction-optimizing traversal "
                "must not lose to the best static push engine)")
        m = TRACER_RE.search(derived)
        if m and float(m.group(1)) < MIN_TRACER_RATIO:
            failures.append(
                f"{name}: disabled_tracer_ratio={m.group(1)} < "
                f"{MIN_TRACER_RATIO} (a disabled tracer must not slow "
                "the serving path)")
        m = SSSP_RE.search(derived)
        if m and float(m.group(1)) < MIN_SSSP_SPEEDUP:
            failures.append(
                f"{name}: sssp_bucketed_vs_lockstep={m.group(1)} < "
                f"{MIN_SSSP_SPEEDUP} (bucketed weighted dispatch must "
                "not lose to one lockstep batch)")
        m = MULTIQUERY_RE.search(derived)
        if m and float(m.group(1)) < MIN_MULTIQUERY_SPEEDUP:
            failures.append(
                f"{name}: multiquery_vs_bucketed={m.group(1)} < "
                f"{MIN_MULTIQUERY_SPEEDUP} (the packed-word coalesced "
                "dispatch must amortize its one sweep over 32 lanes)")
        m = ADMISSION_RE.search(derived)
        if m and float(m.group(1)) < MIN_ADMISSION_RATIO:
            failures.append(
                f"{name}: admission_overhead_ratio={m.group(1)} < "
                f"{MIN_ADMISSION_RATIO} (the guard ladder must be ~free "
                "on admitted traffic)")
    return failures


def _median(xs: list[float]) -> float:
    s = sorted(xs)
    n = len(s)
    return s[n // 2] if n % 2 else (s[n // 2 - 1] + s[n // 2]) / 2.0


def drift_report(rows: dict, history_path: str) -> list[str]:
    """NON-GATING: current us_per_call vs the median of the last
    ``HISTORY_WINDOW`` history entries, one line per cell that moved more
    than ``DRIFT_FLAG``x either way (plus a one-line summary).  Returns the
    report lines; never fails the gate — absolute wall times are machine
    weather, the gated cells are all paired ratios."""
    try:
        with open(history_path) as f:
            entries = [json.loads(line) for line in f if line.strip()]
    except (OSError, json.JSONDecodeError) as e:
        return [f"drift: cannot read {history_path}: {e}"]
    if not entries:
        return [f"drift: {history_path} is empty"]
    window = entries[-HISTORY_WINDOW:]
    lines = [f"drift report vs last {len(window)} history run(s) "
             f"in {history_path} (non-gating):"]
    flagged = compared = 0
    for name, row in sorted(bench_rows(rows).items()):
        us = row.get("us_per_call")
        past = [e["rows"][name] for e in window
                if isinstance(e.get("rows"), dict) and name in e["rows"]]
        if us is None or not past:
            continue
        compared += 1
        base = _median(past)
        ratio = us / max(base, 1e-9)
        if ratio > DRIFT_FLAG or ratio < 1.0 / DRIFT_FLAG:
            flagged += 1
            lines.append(f"  DRIFT {name}: {us:.1f}us vs median "
                         f"{base:.1f}us ({ratio:.2f}x)")
    lines.append(f"drift: {flagged} flagged of {compared} compared "
                 f"cell(s), window={len(window)}")
    return lines


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    history = None
    if "--history" in argv:
        i = argv.index("--history")
        history = argv[i + 1]
        del argv[i:i + 2]
    path = (argv or ["BENCH_bfs.json"])[0]
    if history is None and os.path.exists("BENCH_history.jsonl"):
        history = "BENCH_history.jsonl"
    with open(path) as f:
        rows = json.load(f)
    failures = check(rows)
    if history is not None:
        for line in drift_report(rows, history):
            print(line)
    if failures:
        print(f"PERF GATE FAILED ({path}):")
        for msg in failures:
            print(f"  FAIL {msg}")
        return 1
    gated = sum(1 for r in bench_rows(rows).values()
                if any(g.search(r.get("derived", "")) for g in GATES))
    print(f"perf gate OK: {gated} gated cell(s) of "
          f"{len(bench_rows(rows))} in {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
