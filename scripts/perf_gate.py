"""CI perf gate over the quick-bench artifact (BENCH_bfs.json).

Fails (exit 1) when the perf trajectory regresses past the ROADMAP bars:

* any cell reporting ``per_root_speedup_vs_sequential`` below 1.0 — the
  batched serving path must beat a sequential loop per root (this cell was
  0.41 before reach bucketing; the gate keeps it from regressing);
* any planner cell reporting ``vs_best_forced`` above 1.2 — the planner's
  selection regret bar;
* the calibration gate: any cell reporting ``calibrated_vs_best_forced``
  above the same 1.2 bar — REFIT cost constants (the serving feedback
  loop, ``exp_serving/calibrated_regret``) must not make engine selection
  worse than the bar the hand-calibrated prior meets;
* the plan-store gate: any cell reporting ``rehydrated_match`` other than
  1 — a session rehydrated from a plan store must produce row-identical
  results to the cold-planned session (``exp_serving/rehydrated_serving``);
* the direction-optimizing gate: any cell reporting
  ``diropt_vs_push_only`` below 1.0 — the per-level push/pull switching
  engine must not lose to the best static push engine on the
  wide-frontier quick cell (``exp_direction/diropt_wide/d8``: a dense
  E > V graph, the regime the optimization targets; the ratio is
  measured PAIRED so shared-host drift cancels).  The exp1 tree cells
  (``exp1/diropt/d{4,8}``) report under ``diropt_vs_push_only_d{D}``
  (informational, ungated): on a tree E == V-1 and diropt is
  push-parity by construction — gating a statistical tie would fail CI
  on machine weather.  The hybrid variant likewise reports under
  ``diropt_hybrid_vs_push_only``.

The lockstep reference cell deliberately reports its ratio under a
different key (``lockstep_vs_sequential``) so the gate does not fire on the
kept-for-comparison regression baseline.

Usage: python scripts/perf_gate.py [BENCH_bfs.json]
"""
from __future__ import annotations

import json
import re
import sys

SPEEDUP_RE = re.compile(r"(?:^|,)per_root_speedup_vs_sequential=([\d.]+)")
REGRET_RE = re.compile(r"(?:^|,)vs_best_forced=([\d.]+)")
CAL_REGRET_RE = re.compile(r"(?:^|,)calibrated_vs_best_forced=([\d.]+)")
REHYDRATED_RE = re.compile(r"(?:^|,)rehydrated_match=(\d+)")
DIROPT_RE = re.compile(r"(?:^|,)diropt_vs_push_only=([\d.]+)")

MIN_PER_ROOT_SPEEDUP = 1.0
MAX_PLANNER_REGRET = 1.2
MIN_DIROPT_SPEEDUP = 1.0

GATES = (SPEEDUP_RE, REGRET_RE, CAL_REGRET_RE, REHYDRATED_RE, DIROPT_RE)


def check(rows: dict) -> list[str]:
    failures = []
    for name, row in sorted(rows.items()):
        derived = row.get("derived", "")
        m = SPEEDUP_RE.search(derived)
        if m and float(m.group(1)) < MIN_PER_ROOT_SPEEDUP:
            failures.append(
                f"{name}: per_root_speedup_vs_sequential={m.group(1)} "
                f"< {MIN_PER_ROOT_SPEEDUP} (batched serving must beat "
                "the sequential loop)")
        m = REGRET_RE.search(derived)
        if m and float(m.group(1)) > MAX_PLANNER_REGRET:
            failures.append(
                f"{name}: vs_best_forced={m.group(1)} > "
                f"{MAX_PLANNER_REGRET} (planner selection regret bar)")
        m = CAL_REGRET_RE.search(derived)
        if m and float(m.group(1)) > MAX_PLANNER_REGRET:
            failures.append(
                f"{name}: calibrated_vs_best_forced={m.group(1)} > "
                f"{MAX_PLANNER_REGRET} (refit constants must not worsen "
                "planner regret)")
        m = REHYDRATED_RE.search(derived)
        if m and int(m.group(1)) != 1:
            failures.append(
                f"{name}: rehydrated_match={m.group(1)} != 1 "
                "(plan-store-rehydrated serving must match cold-plan "
                "results)")
        m = DIROPT_RE.search(derived)
        if m and float(m.group(1)) < MIN_DIROPT_SPEEDUP:
            failures.append(
                f"{name}: diropt_vs_push_only={m.group(1)} < "
                f"{MIN_DIROPT_SPEEDUP} (direction-optimizing traversal "
                "must not lose to the best static push engine)")
    return failures


def main(argv=None) -> int:
    path = (argv or sys.argv[1:] or ["BENCH_bfs.json"])[0]
    with open(path) as f:
        rows = json.load(f)
    failures = check(rows)
    if failures:
        print(f"PERF GATE FAILED ({path}):")
        for msg in failures:
            print(f"  FAIL {msg}")
        return 1
    gated = sum(1 for r in rows.values()
                if any(g.search(r.get("derived", "")) for g in GATES))
    print(f"perf gate OK: {gated} gated cell(s) of {len(rows)} in {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
