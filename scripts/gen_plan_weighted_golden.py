"""Regenerate ``tests/golden/plan_weighted.json``.

The snapshot freezes the schema-v6 machine-readable plan document for the
canonical weighted shortest-path query on the seeded random graph used
throughout ``tests/test_semiring.py``: candidate ranking (the two weighted
engines), per-engine skip reasons, per-operator byte/row estimates priced
with the DEFAULT cost constants, and the logical section's
``workload``/``weight_col`` axes.  External tooling diffs this across PRs,
so an unintended weighted-costing or schema change must show up here.

Usage: PYTHONPATH=src python scripts/gen_plan_weighted_golden.py
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core.engine import Dataset, EngineCaps
from repro.core.table import ColumnTable
from repro.planner import explain_json
from repro.planner.ast import weighted_listing

OUT = os.path.join(os.path.dirname(__file__), "..", "tests", "golden",
                   "plan_weighted.json")


def main() -> None:
    rng = np.random.default_rng(21)
    v, e = 50, 140
    table = ColumnTable.from_numpy({
        "id": np.arange(e, dtype=np.int32),
        "from": rng.integers(0, v, e).astype(np.int32),
        "to": rng.integers(0, v, e).astype(np.int32),
        "name": np.zeros((e, 4), np.float32),
        "w": rng.uniform(0.5, 3.0, e).astype(np.float32),
    })
    ds = Dataset.prepare(table, v)
    caps = EngineCaps(frontier=e + 16, result=4 * e + 16)
    sql = weighted_listing("shortest_path", root=0, depth=6, weight_col="w")
    doc = explain_json(sql, ds, caps=caps)
    with open(OUT, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote schema-v{doc['schema_version']} weighted plan to {OUT}")


if __name__ == "__main__":
    main()
