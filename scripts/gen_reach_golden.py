"""Regenerate ``tests/golden/reach_parity.json``.

The golden freezes the EXACT reach (boolean BFS) output of every engine x
every legal direction on two seeded random graphs: result positions in
emission order, per-row ids/depths, final depth, overflow and count.  The
snapshot was generated BEFORE the semiring value-plane refactor landed, so
``tests/test_semiring.py::test_reach_golden_parity`` proves the refactored
operators are bit-identical for the boolean case — not merely row-set
equal.

Usage: PYTHONPATH=src python scripts/gen_reach_golden.py
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core.engine import (ENGINE_NAMES, Dataset, EngineCaps,
                               RecursiveQuery, run_query)
from repro.core.table import ColumnTable

OUT = os.path.join(os.path.dirname(__file__), "..", "tests", "golden",
                   "reach_parity.json")

GRAPHS = (
    dict(seed=3, num_vertices=17, num_edges=40, max_depth=4),
    dict(seed=12, num_vertices=29, num_edges=70, max_depth=6),
)
DIRECTIONS = ("outbound", "inbound", "both")


def _dataset(seed: int, num_vertices: int, num_edges: int) -> Dataset:
    rng = np.random.default_rng(seed)
    src = rng.integers(0, num_vertices, size=num_edges)
    dst = rng.integers(0, num_vertices, size=num_edges)
    table = ColumnTable.from_numpy({
        "id": np.arange(num_edges, dtype=np.int32),
        "from": src.astype(np.int32),
        "to": dst.astype(np.int32),
        "name": rng.standard_normal((num_edges, 4)).astype(np.float32),
    })
    return Dataset.prepare(table, num_vertices)


def _cell(r) -> dict:
    cell = {
        "count": int(r.count),
        "depth": int(r.depth),
        "overflow": bool(r.overflow),
        "positions": np.asarray(r.positions).tolist(),
        "ids": np.asarray(r.values["id"]).tolist(),
    }
    if r.row_depths is not None:
        cell["row_depths"] = np.asarray(r.row_depths).tolist()
    return cell


def main() -> None:
    doc = {}
    for g in GRAPHS:
        ds = _dataset(g["seed"], g["num_vertices"], g["num_edges"])
        caps = EngineCaps(frontier=g["num_edges"] + 16,
                          result=4 * g["num_edges"] + 16)
        for engine in ENGINE_NAMES:
            for direction in DIRECTIONS:
                q = RecursiveQuery(engine=engine, max_depth=g["max_depth"],
                                   payload_cols=0, caps=caps,
                                   direction=direction)
                try:
                    r = run_query(q, ds, root=0)
                except ValueError:
                    continue  # engine does not support this direction
                key = f"g{g['seed']}/{engine}/{direction}"
                doc[key] = _cell(r)
    with open(OUT, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {len(doc)} cells to {OUT}")


if __name__ == "__main__":
    main()
