"""CI trace checker: validate a JSONL trace written by ``repro.obs``.

Checks (exit 1 with a reason on the first violation):

* the first record is a ``header`` with the supported ``schema_version``;
* every subsequent record is a ``span`` or ``event`` with its required
  fields (spans: ``id``/``parent``/``name``/``ts_us``/``dur_us``/``attrs``;
  events: ``name``/``parent``/``ts_us``/``attrs``) and sane types;
* span ids are unique, parents reference REAL span ids, and no span is its
  own ancestor (the parent graph is a forest);
* every child span nests in TIME inside its parent (child interval within
  the parent interval, small float slack) — spans are recorded on exit, so
  stream order is children-first; the time containment is the invariant;
* at least ``--min-spans`` spans (default 1) — a trivially empty trace in
  CI means the tracer was not actually installed.

Usage: python scripts/check_trace.py TRACE.jsonl [--min-spans N]
"""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

SLACK_US = 5.0          # float/clock slack for the nesting containment


def check_trace(records: list[dict], min_spans: int = 1) -> list[str]:
    """All violations found in an already-parsed record list (header
    first).  Empty list == valid."""
    errors = []
    spans = {}
    for i, rec in enumerate(records[1:], start=1):
        t = rec.get("type")
        if t == "span":
            for field, typ in (("id", int), ("name", str), ("ts_us", (int, float)),
                               ("dur_us", (int, float)), ("attrs", dict)):
                if not isinstance(rec.get(field), typ):
                    errors.append(f"record {i}: span missing/bad {field!r}")
            if "parent" not in rec:
                errors.append(f"record {i}: span missing 'parent'")
            sid = rec.get("id")
            if sid in spans:
                errors.append(f"record {i}: duplicate span id {sid}")
            elif isinstance(sid, int):
                spans[sid] = rec
        elif t == "event":
            for field, typ in (("name", str), ("ts_us", (int, float)),
                               ("attrs", dict)):
                if not isinstance(rec.get(field), typ):
                    errors.append(f"record {i}: event missing/bad {field!r}")
            if "parent" not in rec:
                errors.append(f"record {i}: event missing 'parent'")
        else:
            errors.append(f"record {i}: unknown record type {t!r}")

    for sid, rec in spans.items():
        parent = rec.get("parent")
        if parent is None:
            continue
        if parent not in spans:
            errors.append(f"span {sid} ({rec.get('name')}): parent {parent} "
                          "is not a recorded span")
            continue
        # no self-ancestry (forest check walks to a root or repeats)
        seen, p = {sid}, parent
        while p is not None:
            if p in seen:
                errors.append(f"span {sid}: ancestry cycle via {p}")
                break
            seen.add(p)
            p = spans[p].get("parent") if p in spans else None
        # time containment
        par = spans[parent]
        if rec["ts_us"] < par["ts_us"] - SLACK_US or \
           rec["ts_us"] + rec["dur_us"] > \
           par["ts_us"] + par["dur_us"] + SLACK_US:
            errors.append(
                f"span {sid} ({rec.get('name')}) "
                f"[{rec['ts_us']:.1f}, {rec['ts_us'] + rec['dur_us']:.1f}] "
                f"does not nest in parent {parent} ({par.get('name')}) "
                f"[{par['ts_us']:.1f}, {par['ts_us'] + par['dur_us']:.1f}]")

    if len(spans) < min_spans:
        errors.append(f"only {len(spans)} span(s), expected >= {min_spans} "
                      "(tracer not installed?)")
    return errors


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    min_spans = 1
    if "--min-spans" in argv:
        i = argv.index("--min-spans")
        min_spans = int(argv[i + 1])
        del argv[i:i + 2]
    if not argv:
        print("usage: check_trace.py TRACE.jsonl [--min-spans N]")
        return 2
    path = argv[0]

    from repro.obs import read_jsonl
    try:
        records = read_jsonl(path)       # validates header + version
    except (OSError, ValueError) as e:
        print(f"TRACE CHECK FAILED: {e}")
        return 1
    errors = check_trace(records, min_spans=min_spans)
    if errors:
        print(f"TRACE CHECK FAILED ({path}):")
        for msg in errors:
            print(f"  FAIL {msg}")
        return 1
    n_spans = sum(1 for r in records if r.get("type") == "span")
    n_events = sum(1 for r in records if r.get("type") == "event")
    print(f"trace OK: {n_spans} span(s), {n_events} event(s), "
          f"schema {records[0]['schema_version']} in {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
